"""Worker-process tests: one spawned engine behind the control protocol.

A raw-mode worker (tiny deterministic `ServingEngine`, same shape as the
soak suite's engines) is spawned ONCE per module and driven over the pipe;
a local twin engine built from the identical `WorkerSpec` payload replays
the same requests in-process. Temperature-0 decoding from identical seeds
means the worker's wire results must match the local engine token-for-token
— the process boundary is not allowed to change a single output.

Executor-mode (full CarbonCall query surface over the wire) is covered by a
`slow`-marked test: it builds the reduced qwen2-7b arch in the child, which
is a real jit warmup.
"""
import dataclasses

import jax
import pytest

from repro.config import ModelConfig, RuntimeConfig
from repro.launch.workers import (EngineActor, ProtocolError, WorkerSpec,
                                  launch_workers, shutdown_workers)
from repro.models import get_model
from repro.quant import quantize_tree
from repro.serving import (EngineConfig, EngineStats, ServingEngine,
                           SessionRequest, VirtualClock)
from repro.sharding.param import init_params

CFG = ModelConfig(name="worker-tiny", family="transformer", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256)
ECFG = EngineConfig(max_batch=3, max_seq=64, kv_layout="paged",
                    block_size=8, num_blocks=16)
SPEC = WorkerSpec(config=ECFG, seed=0,
                  model_cfg=dataclasses.asdict(CFG), label="test-raw")

# block-aligned shared prefix + distinct tails: exercises the prefix cache
# and still makes every stream unique
PROMPTS = [[3] * 16 + [10 + i, 20 + i, 30 + i] for i in range(6)]


@pytest.fixture(scope="module")
def worker():
    ws = launch_workers([SPEC])
    yield ws[0]
    shutdown_workers(ws)


@pytest.fixture(scope="module")
def local():
    """In-process twin built from the SAME spec payload the worker got."""
    model = get_model(CFG)
    pspec = model.param_spec()
    params = init_params(pspec, jax.random.PRNGKey(SPEC.seed))
    variants = {v: quantize_tree(params, pspec, v)
                for v in ECFG.variants}
    eng = ServingEngine(CFG, variants[ECFG.variants[0]], RuntimeConfig(),
                        config=ECFG, clock=VirtualClock())
    eng.variant_name = ECFG.variants[0]
    return eng


def _sreq(prompt, **kw):
    kw.setdefault("max_new_tokens", 5)
    kw.setdefault("eos_id", -1)
    return SessionRequest(prompt=list(prompt), temperature=0.0, **kw)


def test_submit_settle_matches_local(worker, local):
    """Token-for-token parity across the process boundary."""
    reqs = [_sreq(p) for p in PROMPTS[:3]]
    rids = [worker.submit(r) for r in reqs]
    results = worker.settle(rids)

    client = local.client()
    handles = [client.submit(r) for r in reqs]
    client.settle(handles)

    for rr, h in zip(results, handles):
        assert rr.status == "done" == h.poll()
        assert list(rr.output) == list(h.request.output)
        assert rr.queue_wait_s == pytest.approx(h.request.queue_wait_s)


def test_poll_and_cancel(worker):
    rid = worker.submit(_sreq(PROMPTS[3], max_new_tokens=12))
    assert worker.call("poll", rid=rid)["status"] == "waiting"
    assert worker.call("cancel", rid=rid)["cancelled"] is True
    assert worker.call("poll", rid=rid)["status"] == "cancelled"
    worker.call("drain")                 # cancelled stream leaves no work


def test_error_reply_keeps_worker_alive(worker):
    """Protocol errors come back as replies; the process must survive."""
    with pytest.raises(ProtocolError, match="unknown op"):
        worker.call("frobnicate")
    with pytest.raises(ProtocolError, match="unknown variant"):
        worker.call("swap", variant="fp64")
    with pytest.raises(ProtocolError, match="query ops need an executor"):
        worker.call("query", query={"v": 1})
    assert worker.call("clock")["t"] >= 0.0      # still serving


def test_swap_and_clock_ops(worker):
    t0 = worker.call("clock")["t"]
    assert worker.call("advance", dt=2.5)["t"] == pytest.approx(t0 + 2.5)
    # rebase anchors forward only — never rewinds the worker's timeline
    t1 = worker.call("rebase", t=t0 + 10.0)["t"]
    assert t1 == pytest.approx(t0 + 10.0)
    assert worker.call("rebase", t=0.0)["t"] == pytest.approx(t1)
    out = worker.call("swap", variant="q4")
    assert out["variant"] == "q4" and out["swap_count"] >= 1
    worker.call("swap", variant="q8")    # back to boot weights


def test_stats_schema_over_the_wire(worker):
    st = worker.stats()
    assert isinstance(st, EngineStats)
    assert st.admitted >= 3              # the streams settled above
    assert st.cancelled >= 1             # (a waiting cancel never admits)
    assert st.tokens_emitted > 0
    assert st.swap_count >= 2
    assert st.prefix_cache.get("entries", 0) >= 1   # shared prefix cached
    assert "interactive" in st.tiers or "default" in st.tiers


def test_build_failure_ships_error():
    bad = WorkerSpec(config=EngineConfig(), hw="pdp11", label="bad-hw")
    with pytest.raises(ProtocolError, match="failed to build"):
        launch_workers([bad], timeout=120.0)


def test_actor_in_process_round_trip():
    """The worker-side dispatcher is drivable without a process: same ops,
    same wire payloads — what the soak suite leans on."""
    actor = EngineActor(SPEC)
    rid = actor.handle("submit", {"request":
                                  {"v": 1, "prompt": PROMPTS[0],
                                   "max_new_tokens": 4, "eos_id": -1}})["rid"]
    out = actor.handle("settle", {"rids": [rid]})
    assert out["results"][0]["status"] == "done"
    assert len(out["results"][0]["output"]) == 4
    assert actor.handle("check", {"flush": False})["violations"] == []


def test_check_invariants_clean(worker):
    """All streams terminal -> the worker's own invariant sweep is clean.
    Runs LAST: `flush=True` clears the prefix cache as part of the refcount
    reconciliation."""
    worker.call("drain")
    assert worker.call("check", flush=True)["violations"] == []


@pytest.mark.slow
def test_executor_mode_query_surface():
    """Full CarbonCall query path over the wire: energy/carbon attribution
    crosses the boundary inside the execution record."""
    from repro.serving import QuerySpec

    spec = WorkerSpec(config=EngineConfig(max_batch=2, max_seq=128),
                      label="test-exec")
    ws = launch_workers([spec])
    try:
        w = ws[0]
        qids = [w.query(QuerySpec(n_tools=2, n_calls=1, tier="interactive")),
                w.query(QuerySpec(n_tools=3, n_calls=2, variant="q4",
                                  tier="batch"))]
        rep = w.call("settle_queries", qids=qids)
        assert len(rep["executions"]) == 2
        for ex in rep["executions"]:
            assert ex["energy_j"] > 0.0
            assert ex["decode_tokens"] > 0
        st = EngineStats.from_wire(rep["stats"])
        assert st.admitted >= 2
    finally:
        shutdown_workers(ws)
